"""The static-analysis layer (repro/analysis/).

Per rule: a bad fixture produces exactly the expected finding, a good
fixture stays clean, a ``# repro: noqa(rule)`` suppression is honored, and
a stale suppression is itself flagged.  Plus: the committed golden counts
match a fresh run over the tree, the dense-free proof holds for every
registered pack kernel (and catches a deliberately dense function), and
the REFERENCE_FOLD extraction of PR 10 is pinned to its pre-existing
literal so reference trajectories are unchanged.
"""

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import framework
from repro.analysis import hlo
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.docs import discover_doctests
from repro.core import efbv

REPO = Path(__file__).resolve().parents[1]


def run_rules(tmp_path, code, rule_names, relpath="mod.py"):
    """-> (findings, suppressed) of the named rules over a fixture file."""
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    subset = {n: framework.RULES[n] for n in rule_names}
    kept, suppressed, _errors = framework.analyze_file(p, subset)
    return kept, suppressed


# ---------------------------------------------------------------------------
# R1 prng-reuse
# ---------------------------------------------------------------------------


def test_r1_flags_double_consumption(tmp_path):
    bad = """
    import jax

    def f(key):
        a = jax.random.normal(key, (3,))
        b = jax.random.uniform(key, (3,))
        return a + b
    """
    kept, _ = run_rules(tmp_path, bad, ["prng-reuse"])
    assert [f.rule for f in kept] == ["prng-reuse"]
    assert "already consumed" in kept[0].message
    assert kept[0].line == 6  # the second consumption is the defect site


def test_r1_split_interleaving_is_clean(tmp_path):
    good = """
    import jax

    def f(key):
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (3,))
        b = jax.random.uniform(k2, (3,))
        return a + b
    """
    kept, _ = run_rules(tmp_path, good, ["prng-reuse"])
    assert kept == []


def test_r1_early_return_branches_are_independent(tmp_path):
    # the Participation.sample_mask shape: mutually-exclusive `if: return`
    # arms each consume the key once -- no reuse on any real path
    good = """
    import jax

    def sample(kind, key, n):
        if kind == "bernoulli":
            return jax.random.bernoulli(key, 0.5, (n,))
        if kind == "fixed":
            return jax.random.permutation(key, n)
        return None
    """
    kept, _ = run_rules(tmp_path, good, ["prng-reuse"])
    assert kept == []


def test_r1_flags_loop_carried_reuse_and_accepts_fold_in(tmp_path):
    bad = """
    import jax

    def f(key):
        out = []
        for i in range(4):
            out.append(jax.random.normal(key, (2,)))
        return out
    """
    kept, _ = run_rules(tmp_path, bad, ["prng-reuse"])
    assert [f.rule for f in kept] == ["prng-reuse"]
    assert "loop iterations" in kept[0].message

    good = """
    import jax

    def f(key):
        out = []
        for i in range(4):
            out.append(jax.random.normal(jax.random.fold_in(key, i), (2,)))
        return out
    """
    kept, _ = run_rules(tmp_path, good, ["prng-reuse"])
    assert kept == []


def test_r1_flags_literal_fold_constants(tmp_path):
    bad = """
    import jax

    def f(key):
        return jax.random.fold_in(key, 0xDEADBEEF)
    """
    kept, _ = run_rules(tmp_path, bad, ["prng-reuse"])
    assert [f.rule for f in kept] == ["prng-reuse"]
    assert "*_FOLD" in kept[0].message

    good = """
    import jax
    from repro.core.efbv import DOWNLINK_FOLD

    def f(key, j):
        a = jax.random.fold_in(key, DOWNLINK_FOLD)   # registry name: fine
        b = jax.random.fold_in(key, 3)               # small index: fine
        return a, b, jax.random.fold_in(key, j)
    """
    kept, _ = run_rules(tmp_path, good, ["prng-reuse"])
    assert kept == []


# ---------------------------------------------------------------------------
# R2 low-precision-accumulation
# ---------------------------------------------------------------------------


def test_r2_flags_bf16_contractions_and_reductions(tmp_path):
    bad = """
    import jax.numpy as jnp

    def f(a, b):
        x = a.astype(jnp.bfloat16)
        d = jnp.dot(x, b)
        m = x @ b
        s = x.sum()
        return d, m, s
    """
    kept, _ = run_rules(tmp_path, bad, ["low-precision-accumulation"])
    assert [f.rule for f in kept] == ["low-precision-accumulation"] * 3
    assert {f.line for f in kept} == {6, 7, 8}


def test_r2_preferred_element_type_or_upcast_is_clean(tmp_path):
    good = """
    import jax.numpy as jnp

    def f(a, b):
        x = a.astype(jnp.bfloat16)
        d = jnp.dot(x, b, preferred_element_type=jnp.float32)
        s = x.sum(dtype=jnp.float32)
        y = x.astype(jnp.float32)
        m = y @ b
        dyn = a.astype(b.dtype) @ b      # dynamic dtype: not statically low
        return d, s, m, dyn
    """
    kept, _ = run_rules(tmp_path, good, ["low-precision-accumulation"])
    assert kept == []


# ---------------------------------------------------------------------------
# R3 hot-path-ravel
# ---------------------------------------------------------------------------


def test_r3_flags_ravel_only_in_hot_paths(tmp_path):
    code = """
    def f(x, tree):
        from jax.flatten_util import ravel_pytree
        flat, unravel = ravel_pytree(tree)
        return x.ravel(), flat
    """
    kept, _ = run_rules(tmp_path, code, ["hot-path-ravel"],
                        relpath="kernels/k.py")
    assert [f.rule for f in kept] == ["hot-path-ravel"] * 2

    kept, _ = run_rules(tmp_path, code, ["hot-path-ravel"],
                        relpath="models/m.py")
    assert kept == []


# ---------------------------------------------------------------------------
# R4 spec-fingerprint-stability
# ---------------------------------------------------------------------------


def test_r4_flags_post_v1_field_without_delete_guard(tmp_path):
    bad = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class ExperimentSpec:
        compressor: str = "topk:8"
        pipeline: str = "off"

        def to_dict(self):
            return {"compressor": self.compressor, "pipeline": self.pipeline}
    """
    kept, _ = run_rules(tmp_path, bad, ["spec-fingerprint-stability"])
    assert [f.rule for f in kept] == ["spec-fingerprint-stability"]
    assert "pipeline" in kept[0].message
    assert "fingerprint" in kept[0].message


def test_r4_flags_unfrozen_class_and_bad_defaults(tmp_path):
    bad = """
    import dataclasses

    @dataclasses.dataclass
    class ServeSpec:
        replicas: int = 2
        slots: list = dataclasses.field(default_factory=list)
        prompt: int
    """
    kept, _ = run_rules(tmp_path, bad, ["spec-fingerprint-stability"])
    msgs = "\n".join(f.message for f in kept)
    assert "frozen=True" in msgs
    assert "slots" in msgs and "immutable JSON scalar" in msgs
    assert "prompt" in msgs and "no default" in msgs


def test_r4_flags_guard_default_mismatch(tmp_path):
    bad = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class ExperimentSpec:
        compressor: str = "topk:8"
        serve: str = ""

        def to_dict(self):
            d = {"compressor": self.compressor, "serve": self.serve}
            if self.serve == "none":
                del d["serve"]
            return d
    """
    kept, _ = run_rules(tmp_path, bad, ["spec-fingerprint-stability"])
    assert len(kept) == 1
    assert "default-constructed spec would" in kept[0].message


def test_r4_clean_on_guarded_spec_and_on_the_real_one(tmp_path):
    good = """
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class ExperimentSpec:
        compressor: str = "topk:8"
        pipeline: str = "off"

        def to_dict(self):
            d = {"compressor": self.compressor, "pipeline": self.pipeline}
            if self.pipeline == "off":
                del d["pipeline"]
            return d
    """
    kept, _ = run_rules(tmp_path, good, ["spec-fingerprint-stability"])
    assert kept == []

    # the shipped spec module is the rule's real target: it must hold
    subset = {"spec-fingerprint-stability":
              framework.RULES["spec-fingerprint-stability"]}
    kept, _, _ = framework.analyze_file(
        REPO / "src" / "repro" / "core" / "spec.py", subset)
    assert kept == []


# ---------------------------------------------------------------------------
# R5 pallas-kernel-hygiene
# ---------------------------------------------------------------------------


def test_r5_flags_closure_missing_specs_and_f64(tmp_path):
    bad = """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def wrapper(x, lam):
        scale = lam * 2.0

        def _scale_kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] * scale
            tmp = jnp.full((4, 4), 0.5)
            big = x_ref[...].astype(jnp.float64)

        return pl.pallas_call(_scale_kernel,
                              out_shape=jax.ShapeDtypeStruct(x.shape,
                                                             x.dtype))(x)
    """
    kept, _ = run_rules(tmp_path, bad, ["pallas-kernel-hygiene"],
                        relpath="kernels/k.py")
    msgs = "\n".join(f.message for f in kept)
    assert "closes over 'scale'" in msgs
    assert "without in_specs" in msgs and "without out_specs" in msgs
    assert "f64 inside a kernel" in msgs
    assert "explicit dtype" in msgs


def test_r5_clean_kernel_and_outside_kernels_dir(tmp_path):
    good = """
    import functools
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _scale_kernel(x_ref, o_ref, *, scale: float):
        o_ref[...] = x_ref[...] * scale
        tmp = jnp.full((4, 4), 0.5, jnp.float32)

    def wrapper(x, lam):
        return pl.pallas_call(
            functools.partial(_scale_kernel, scale=float(lam)),
            in_specs=[pl.BlockSpec(x.shape, lambda: (0, 0))],
            out_specs=pl.BlockSpec(x.shape, lambda: (0, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
    """
    kept, _ = run_rules(tmp_path, good, ["pallas-kernel-hygiene"],
                        relpath="kernels/k.py")
    assert kept == []

    # same bad code outside kernels/ is out of the rule's scope
    bad = "def _k_kernel(x_ref):\n    y = x_ref[...].astype('float64')\n"
    p = tmp_path / "models" / "m.py"
    p.parent.mkdir(exist_ok=True)
    p.write_text(bad)
    subset = {"pallas-kernel-hygiene":
              framework.RULES["pallas-kernel-hygiene"]}
    kept, _, _ = framework.analyze_file(p, subset)
    assert kept == []


# ---------------------------------------------------------------------------
# R6 shard-map-spec-consistency
# ---------------------------------------------------------------------------


def test_r6_flags_bad_axis_arity_and_collective(tmp_path):
    bad = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro import compat

    def phase(a, b):
        return jax.lax.psum(a + b, "model")

    def run(mesh, x, y):
        return compat.shard_map(phase, mesh=mesh,
                                in_specs=(P("rows"),),
                                out_specs=P("data"))(x, y)
    """
    kept, _ = run_rules(tmp_path, bad, ["shard-map-spec-consistency"])
    msgs = "\n".join(f.message for f in kept)
    assert "'rows' is not a mesh axis" in msgs
    assert "in_specs has 1 entries but callee 'phase' takes 2" in msgs
    assert "psum over axis 'model'" in msgs  # specs only name rows/data


def test_r6_clean_on_consistent_call(tmp_path):
    good = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro import compat

    def phase(a, b):
        return jax.lax.psum(a + b, "data")

    def run(mesh, x, y):
        return compat.shard_map(phase, mesh=mesh,
                                in_specs=(P("data"), P("data")),
                                out_specs=P("data"))(x, y)
    """
    kept, _ = run_rules(tmp_path, good, ["shard-map-spec-consistency"])
    assert kept == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_is_honored(tmp_path):
    code = """
    def f(x):
        return x.ravel()  # repro: noqa(hot-path-ravel) -- test fixture
    """
    kept, suppressed = run_rules(tmp_path, code, ["hot-path-ravel"],
                                 relpath="kernels/k.py")
    assert kept == []
    assert [f.rule for f in suppressed] == ["hot-path-ravel"]


def test_unused_suppression_is_flagged(tmp_path):
    code = """
    def f(x):
        return x + 1  # repro: noqa(hot-path-ravel)
    """
    kept, _ = run_rules(tmp_path, code, ["hot-path-ravel"],
                        relpath="kernels/k.py")
    assert [f.rule for f in kept] == [framework.UNUSED_SUPPRESSION]
    assert "stale" in kept[0].message


def test_unknown_rule_in_noqa_is_flagged(tmp_path):
    code = "x = 1  # repro: noqa(not-a-rule)\n"
    p = tmp_path / "m.py"
    p.write_text(code)
    kept, _, _ = framework.analyze_file(p)
    assert [f.rule for f in kept] == [framework.UNUSED_SUPPRESSION]
    assert "unknown rule" in kept[0].message


def test_noqa_inside_string_literal_is_not_a_suppression(tmp_path):
    code = '''
    DOC = """example: x.ravel()  # repro: noqa(hot-path-ravel)"""

    def f(x):
        return x.ravel()
    '''
    kept, suppressed = run_rules(tmp_path, code, ["hot-path-ravel"],
                                 relpath="kernels/k.py")
    # the real ravel still fires; the string-embedded noqa neither
    # suppresses anything nor counts as stale
    assert [f.rule for f in kept] == ["hot-path-ravel"]
    assert suppressed == []


# ---------------------------------------------------------------------------
# runner + golden
# ---------------------------------------------------------------------------


def test_cli_exit_codes_and_golden_roundtrip(tmp_path):
    bad_dir = tmp_path / "kernels"
    bad_dir.mkdir()
    (bad_dir / "k.py").write_text("def f(x):\n    return x.ravel()\n")
    assert analysis_main([str(bad_dir)]) == 1
    (bad_dir / "k.py").write_text("def f(x):\n    return x\n")
    assert analysis_main([str(bad_dir)]) == 0

    golden = tmp_path / "g.json"
    assert analysis_main([str(bad_dir), "--write-golden", str(golden)]) == 0
    data = json.loads(golden.read_text())
    assert data["files"] == 1 and data["findings"] == {}
    assert analysis_main([str(bad_dir), "--golden", str(golden)]) == 0
    (bad_dir / "k2.py").write_text("y = 2\n")
    assert analysis_main([str(bad_dir), "--golden", str(golden)]) == 1


def test_committed_golden_matches_fresh_run():
    result = framework.analyze_paths([str(REPO / "src"), str(REPO / "tests")])
    assert result.findings == [] and result.errors == []
    diffs = framework.compare_golden(result, str(REPO / "ANALYSIS_GOLDEN.json"))
    assert diffs == [], diffs


def test_parse_error_is_reported(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    kept, _, _ = framework.analyze_file(p)
    assert [f.rule for f in kept] == ["parse-error"]


# ---------------------------------------------------------------------------
# docs analysis
# ---------------------------------------------------------------------------


def test_docs_doctest_census_counts_examples(tmp_path):
    md = tmp_path / "x.md"
    md.write_text("# t\n\n```\n>>> 1 + 1\n2\n>>> 2 + 2\n4\n```\n")
    n, errors = discover_doctests(md)
    assert n == 2 and errors == []


# ---------------------------------------------------------------------------
# dense-free proofs
# ---------------------------------------------------------------------------


def test_all_registered_pack_kernels_prove_dense_free():
    assert set(hlo.PACK_KERNELS) == {"block_topk_pack", "randk_update",
                                     "qsgd_pack"}
    for name in sorted(hlo.PACK_KERNELS):
        r = hlo.dense_free(name)
        assert r.ok, (name, r.violations)
        assert r.n_pallas_calls >= 1
        assert 0 < r.tile < r.d          # a strict fraction of d per step
        assert r.max_inner <= r.tile     # nothing denser than the tile


def test_dense_free_catches_a_dense_implementation(monkeypatch):
    def _dense_case():
        import jax.numpy as jnp

        d = 1024
        g = jnp.zeros((d,), jnp.float32)
        h = jnp.zeros((d,), jnp.float32)

        def fn(g, h):
            delta = g - h                       # dense d-sized intermediate
            return jnp.where(delta > 0, delta, 0.0)

        return fn, (g, h), d

    monkeypatch.setitem(hlo.PACK_KERNELS, "dense_strawman", _dense_case)
    r = hlo.dense_free("dense_strawman")
    assert not r.ok
    assert any("no pallas_call" in v for v in r.violations)
    assert any("materializes" in v for v in r.violations)


# ---------------------------------------------------------------------------
# the R1 fix of this PR: the reference driver's named fold constant
# ---------------------------------------------------------------------------


def test_reference_fold_pins_pre_existing_trajectories():
    # Run.reference() used the literal 0x5EED before the constant was named;
    # the name must keep the exact value or every recorded reference
    # trajectory (and the bit-identity pins in test_spec.py) shifts
    assert efbv.REFERENCE_FOLD == 0x5EED
