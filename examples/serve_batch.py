"""Batched serving example: mixed request lengths, greedy decode with the
family-appropriate cache (KV for attention archs, recurrent state for SSM).

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-130m
    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-0.5b
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, get_smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=ARCHS)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    key = jax.random.key(0)
    params = model.init(key)

    # a batch of requests with different prompt lengths (padded left-aligned)
    prompt_lens = [5, 11, 8, 3]
    B = len(prompt_lens)
    gen_tokens = 24
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (L,), 0, cfg.vocab)
               for i, L in enumerate(prompt_lens)]

    cache = model.init_cache(B, args.max_len)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, cfg.encoder_frames, cfg.d_model)) * 0.1
        cache = model.encode_cross_cache(params, frames, cache)

    @jax.jit
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None], cache

    max_prompt = max(prompt_lens)
    # teacher-force prompts (ragged: shorter requests re-feed their last token)
    tok = jnp.stack([p[:1] for p in prompts])
    t0 = time.time()
    for t in range(max_prompt):
        feed = jnp.stack([p[min(t, L - 1):min(t, L - 1) + 1]
                          for p, L in zip(prompts, prompt_lens)])
        nxt, cache = step(params, cache, feed, jnp.int32(t))
    outs = []
    tok = nxt
    for t in range(max_prompt, max_prompt + gen_tokens):
        tok, cache = step(params, cache, tok, jnp.int32(t))
        outs.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    gen = np.stack(outs, 1)
    print(f"[serve_batch] arch={cfg.name}: {B} requests, "
          f"{(max_prompt + gen_tokens) * B / dt:.1f} tok/s")
    for i in range(B):
        print(f"  req{i} (prompt {prompt_lens[i]:2d}): {gen[i][:12].tolist()}")


if __name__ == "__main__":
    main()
