from repro.checkpoint.npz import (  # noqa: F401
    latest_step, restore_checkpoint, restore_latest, save_checkpoint,
    saved_spec,
)
