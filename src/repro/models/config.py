"""Architecture configuration.

One frozen dataclass covers all six assigned families (dense / moe / ssm /
hybrid / encdec-audio / vlm); family-specific fields default to "off".
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attn-free
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention details
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (sums to head_dim//2)
    attn_window: int = 0        # sliding-window size; 0 = full attention
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2-style): one *shared* attention block applied every k layers
    attn_every: int = 0

    # encoder-decoder (whisper-style)
    encoder_layers: int = 0
    encoder_frames: int = 1500

    # modality frontend stub: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    vision_patches: int = 1024  # stub patch-embedding count for VLM inputs

    # attention implementation: 'direct' (materialized S x S scores) or
    # 'chunked' (online-softmax scan over KV chunks; §Perf iteration 3)
    attn_impl: str = "direct"
    # attention weight sharding when heads don't divide the model axis:
    # 'flat' (shard anyway; best for memory-bound) or 'replicate' (no score
    # collectives; best for collective-bound) -- see layers._head_spec
    attn_shard_policy: str = "flat"
    # MoE dispatch groups (0 = one per batch row; §Perf iteration 2)
    moe_groups: int = 0

    # numerics / memory
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    remat: bool = True

    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    # ---- parameter count (used for MODEL_FLOPS = 6 N D in the roofline) -----

    def param_count(self) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd()
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o + (self.n_heads * hd + 2 * self.n_kv_heads * hd if self.qkv_bias else 0)
        mlp = 3 * d * ff  # swiglu: gate + up + down
        norms = 2 * d
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts  # experts + router
        if self.family == "ssm":
            di, st, nh = self.d_inner(), self.ssm_state, self.ssm_heads()
            in_p = d * (2 * di + 2 * st + nh)
            conv = (di + 2 * st) * self.ssm_conv
            out_p = di * d + di  # out proj + gated norm
            per_layer = in_p + conv + out_p + nh * 2 + d  # A, D, norm
            emb = V * d * (1 if self.tie_embeddings else 2)
            return self.n_layers * per_layer + emb + d
        per_layer = attn + mlp + norms
        if self.family == "hybrid":
            di, st, nh = self.d_inner(), self.ssm_state, self.ssm_heads()
            in_p = d * (2 * di + 2 * st + nh)
            conv = (di + 2 * st) * self.ssm_conv
            per_mamba = in_p + conv + di * d + di + nh * 2 + d
            shared_attn = attn + mlp + norms
            emb = V * d * (1 if self.tie_embeddings else 2)
            return self.n_layers * per_mamba + shared_attn + emb + d
        total = self.n_layers * per_layer
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + 3 * d * ff + norms)
            cross = self.n_layers * (q + kv + o + d)
            total += enc + cross
        emb = V * d * (1 if self.tie_embeddings else 2)
        return total + emb + d

    def active_param_count(self) -> int:
        """Active params per token (MoE: only experts_per_tok experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_total = self.param_count()
        all_experts = self.n_experts * 3 * d * ff * self.n_layers
        active = self.experts_per_tok * 3 * d * ff * self.n_layers
        return dense_total - all_experts + active
