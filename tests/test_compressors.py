"""Property tests: every compressor is a certified member of C(eta, omega).

For each compressor we Monte-Carlo estimate the relative bias and variance at
random points (hypothesis generates the points) and assert the certified
constants hold up to sampling error; deterministic compressors are checked
pointwise and exactly.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (
    BlockTopK, CompKK, FracCompKK, FracTopK, Identity, MixKK, Natural, QSGD,
    RandK, ScaledRandK, SignNorm, TopK, bias_variance_estimate, make_compressor,
)

D = 64
N_SAMPLES = 512


def vec(seed, d=D):
    x = jax.random.normal(jax.random.key(seed), (d,))
    return x


DETERMINISTIC = [TopK(8), TopK(1), BlockTopK(16, 4), BlockTopK(32, 1),
                 SignNorm(), FracTopK(0.1), Identity()]
RANDOM = [RandK(8), RandK(1), ScaledRandK(8), CompKK(2, 32), CompKK(1, 32),
          MixKK(2, 8), Natural(), QSGD(4), FracCompKK(0.02, 0.5)]


@pytest.mark.parametrize("comp", DETERMINISTIC, ids=lambda c: repr(c))
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_deterministic_contraction(comp, seed):
    """Deterministic members: ||C(x) - x|| <= eta ||x|| exactly, zero variance."""
    x = vec(seed)
    y = comp(None, x)
    err = float(jnp.linalg.norm(y - x))
    nx = float(jnp.linalg.norm(x))
    assert err <= comp.eta(D) * nx * (1 + 1e-5)
    assert comp.omega(D) == 0.0
    # determinism
    y2 = comp(jax.random.key(0), x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


@pytest.mark.parametrize("comp", RANDOM, ids=lambda c: repr(c))
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_random_class_membership(comp, seed):
    """(i) bias <= eta ||x||, (ii) variance <= omega ||x||^2, within MC error."""
    x = vec(seed)
    bias, var = bias_variance_estimate(comp, jax.random.key(seed ^ 0x5eed), x,
                                       n_samples=N_SAMPLES)
    omega = comp.omega(D)
    eta = comp.eta(D)
    mc_bias = 4.0 * math.sqrt(max(omega, 1e-4) / N_SAMPLES)  # CLT band
    assert bias <= eta + mc_bias, (bias, eta, mc_bias)
    assert var <= omega * (1 + 6.0 / math.sqrt(N_SAMPLES)) + 1e-6, (var, omega)


def test_unbiasedness_exact():
    """U(omega) members are exactly unbiased in expectation (large-sample)."""
    x = vec(3)
    for comp in [RandK(8), Natural(), QSGD(8)]:
        keys = jax.random.split(jax.random.key(0), 4096)
        mean = jnp.mean(jax.vmap(lambda k: comp(k, x))(keys), axis=0)
        rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
        assert rel < 0.1, (type(comp).__name__, rel)


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 2.0, 0.01, 3.0, -0.2, 0.0, 1.0])
    y = TopK(3)(None, x)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray([0.0, -5.0, 2.0, 0.0, 3.0, 0.0, 0.0, 0.0]))


def test_prop4_mix_constants():
    """Prop. 4: mix-(k,k') in B((k+k')/d): empirical contraction matches."""
    k, kp = 2, 8
    comp = MixKK(k, kp)
    alpha = comp.alpha(D)
    assert abs(alpha - (k + kp) / D) < 1e-9  # closed form from the paper
    x = vec(7)
    keys = jax.random.split(jax.random.key(1), 2048)
    errs = jax.vmap(lambda kk: jnp.sum((comp(kk, x) - x) ** 2))(keys)
    emp = float(jnp.mean(errs) / jnp.sum(x * x))
    assert emp <= (1 - alpha) * 1.05


def test_prop5_comp_constants():
    """Prop. 5: comp-(k,k') has eta = sqrt((d-k')/d), omega = (k'-k)/k."""
    k, kp = 2, 32
    comp = CompKK(k, kp)
    assert abs(comp.eta(D) - math.sqrt((D - kp) / D)) < 1e-12
    assert abs(comp.omega(D) - (kp - k) / k) < 1e-12
    # E[C(x)] keeps top-k' coords scaled by 1 (k/k' chance * k'/k scale)
    x = vec(11)
    keys = jax.random.split(jax.random.key(2), 8192)
    mean = jnp.mean(jax.vmap(lambda kk: comp(kk, x))(keys), axis=0)
    _, top_idx = jax.lax.top_k(jnp.abs(x), kp)
    expected = jnp.zeros_like(x).at[top_idx].set(x[top_idx])
    assert float(jnp.linalg.norm(mean - expected)) < 0.15 * float(jnp.linalg.norm(x))


def test_omega_av_independent():
    """Sect 2.4: for n independent compressors the averaged variance is
    omega/n -- checked empirically for rand-1."""
    n, d = 16, 32
    comp = RandK(1)
    xs = jax.random.normal(jax.random.key(0), (n, d))

    def avg_err(key):
        keys = jax.random.split(key, n)
        ys = jax.vmap(lambda k, x: comp(k, x) - x)(keys, xs)
        return jnp.sum(jnp.mean(ys, axis=0) ** 2)

    errs = jax.vmap(avg_err)(jax.random.split(jax.random.key(1), 4096))
    emp = float(jnp.mean(errs))
    bound = comp.omega(d) / n * float(jnp.mean(jnp.sum(xs**2, axis=1)))
    assert emp <= bound * 1.1, (emp, bound)


def test_encode_decode_roundtrip():
    """Sparse wire format reproduces the dense compressor output exactly."""
    x = vec(5, d=100)
    for comp in [TopK(7), BlockTopK(16, 4), FracTopK(0.05), RandK(9), CompKK(3, 20)]:
        key = jax.random.key(3)
        dense = comp(key, x)
        payload = comp.encode(key, x)
        rec = comp.decode(payload, x.size).reshape(x.shape)
        np.testing.assert_allclose(np.asarray(rec), np.asarray(dense), atol=1e-6)


def test_make_compressor_parsing():
    assert isinstance(make_compressor("topk:8"), TopK)
    assert isinstance(make_compressor("comp:1,32"), CompKK)
    assert isinstance(make_compressor("block_topk:256,16"), BlockTopK)
    assert make_compressor("frac_topk:50").frac == 0.05
    with pytest.raises(ValueError):
        make_compressor("nope")


def test_mnice_partial_participation():
    """Sect. 2.4: m-nice sampling has omega = (n-m)/m and the JOINT average
    variance omega_av = (n-m)/(m(n-1)) << omega/1 -- dependent compressors
    whose average is much tamer than any individual one."""
    from repro.core.compressors import MNice
    n, m, d = 16, 4, 8
    comp = MNice(n, m)
    assert abs(comp.omega(d) - (n - m) / m) < 1e-12
    assert abs(comp.omega_av(d, n) - (n - m) / (m * (n - 1))) < 1e-12

    xs = jax.random.normal(jax.random.key(0), (n, d))

    def avg_err(key):
        ys = jax.vmap(lambda i, x: comp.joint_call(key, i, x))(
            jnp.arange(n), xs)
        return jnp.sum((jnp.mean(ys, axis=0) - jnp.mean(xs, axis=0)) ** 2)

    errs = jax.vmap(avg_err)(jax.random.split(jax.random.key(1), 4096))
    emp = float(jnp.mean(errs))
    bound = comp.omega_av(d, n) / n * float(jnp.sum(xs**2))
    assert emp <= bound * 1.1, (emp, bound)
    # exactly m workers participate each round
    ys = jax.vmap(lambda i, x: comp.joint_call(jax.random.key(7), i, x))(
        jnp.arange(n), xs)
    participating = int(jnp.sum(jnp.any(ys != 0, axis=1)))
    assert participating == m


def test_mnice_efbv_converges():
    """EF-BV under partial participation (DIANA-style nu=1, lam=1/(1+omega))
    still converges linearly on a strongly convex problem."""
    from repro.core.compressors import MNice
    from repro.core import EFBV, run_reference, tune
    import numpy as np
    n, d = 8, 12
    key = jax.random.key(2)
    A = jax.random.normal(key, (n, d, d)) / np.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
    b = jax.random.normal(jax.random.key(3), (n, d))
    x_star = jnp.linalg.solve(jnp.mean(Q, 0), jnp.mean(b, 0))
    grads = lambda x: jnp.einsum("nij,j->ni", Q, x) - b

    comp = MNice(n, 2)
    t = tune(comp.eta(d), comp.omega(d), comp.omega_av(d, n), mode="diana",
             L=4.0, Ltilde=4.0)
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    m = run_reference(algo=algo, grad_fn=lambda _k, x: grads(x),
                      x0=jnp.zeros(d), gamma=t.gamma, steps=4000,
                      key=jax.random.key(4), n=n,
                      record=lambda x: jnp.sum((x - x_star) ** 2)).metrics
    assert float(m[-1]) < 1e-6 * float(jnp.sum(x_star**2)), float(m[-1])
