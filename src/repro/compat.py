"""Version tolerance for the jax surface this repo uses.

The code targets the modern API (jax.shard_map with ``axis_names``,
``jax.make_mesh(..., axis_types=...)``, ``jax.lax.pcast``,
``jax.sharding.get_abstract_mesh``); older jaxlibs (0.4.x) ship the same
machinery under different names and defaults:

  * shard_map lives in jax.experimental.shard_map and takes ``auto=`` (the
    complement of ``axis_names``) plus ``check_rep`` instead of the VMA
    type system;
  * Mesh has no axis_types (everything is implicitly Auto under GSPMD);
  * there is no pcast -- without VMA tracking the cotangent of a
    replicated input inside shard_map is already per-shard, so the cast
    is a no-op;
  * there is no abstract-mesh context, so the MoE sharding-constraint
    hints simply don't apply (they are perf hints, not semantics).

Every call site goes through this module so the rest of the codebase can be
written against one API.
"""

from __future__ import annotations

from typing import Any, Iterable, Optional, Sequence, Tuple

import jax

PyTree = Any

#: oldest jax release the shims below are exercised against; the CI tier-1
#: matrix pins one leg to this (keep .github/workflows/ci.yml in sync) so a
#: compat regression surfaces in PR CI, not at seed-repair time.
OLDEST_SUPPORTED_JAX = "0.4.30"

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_PCAST = hasattr(jax.lax, "pcast")

# Old XLA's SPMD partitioner CHECK-fails on partial-auto shard_map when the
# auto ('model') axis has size > 1; callers fall back to an equivalent vmap
# formulation in that regime (see train/trainer.py).
HAS_PARTIAL_AUTO_SHARD_MAP = _HAS_NEW_SHARD_MAP


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """jax.make_mesh with every axis GSPMD-auto, on any jax version."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(f, *, mesh, in_specs, out_specs, manual_axes: Iterable[str]):
    """shard_map manual over ``manual_axes``, GSPMD-auto over the rest."""
    manual = set(manual_axes)
    if _HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(a for a in mesh.axis_names if a not in manual)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def pcast_varying(tree: PyTree, axes: Tuple[str, ...]) -> PyTree:
    """Mark a replicated value as varying over ``axes`` (VMA jaxes only).

    On pre-VMA jax the distinction does not exist: differentiating w.r.t. a
    replicated input inside shard_map already yields the per-shard cotangent,
    so this is the identity.
    """
    if _HAS_PCAST:
        return jax.lax.pcast(tree, tuple(axes), to="varying")
    return tree


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict on any jax version (old
    jaxlibs return a one-element list of dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def abstract_mesh():
    """The ambient abstract mesh, or None when the API doesn't exist."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    return get()


def auto_axes_of(mesh, *, exclude: Tuple[str, ...] = ()) -> Tuple[str, ...]:
    """Names of GSPMD-auto axes of ``mesh`` minus ``exclude``; () if the
    mesh carries no axis-type information (old jax: nothing is manual at the
    GSPMD level, but we can't prove it, so constraints are skipped)."""
    if mesh is None or getattr(mesh, "empty", True):
        return ()
    axis_types = getattr(mesh, "axis_types", None)
    if axis_types is None or not _HAS_AXIS_TYPES:
        return ()
    auto = jax.sharding.AxisType.Auto
    return tuple(n for n, t in zip(mesh.axis_names, axis_types)
                 if n not in exclude and t == auto)
