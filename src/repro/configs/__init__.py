"""Assigned-architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Every architecture in ARCHS is selectable via ``--arch <id>`` in the launch
scripts; smoke variants are reduced (2 layers, d_model <= 512, <= 4 experts)
same-family configs for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

ARCHS = [
    "minitron-8b",
    "granite-moe-3b-a800m",
    "mamba2-130m",
    "phi3-medium-14b",
    "qwen2-vl-2b",
    "dbrx-132b",
    "whisper-medium",
    "minicpm-2b",
    "qwen2-0.5b",
    "zamba2-7b",
]


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> List[str]:
    return list(ARCHS)
