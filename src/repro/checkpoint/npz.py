"""Flat-npz pytree checkpointing (no external deps).

Leaves are addressed by their tree path ('params/layers/attn/wq', ...);
restore validates structure against a template pytree.  Arrays are pulled to
host (sharded arrays are fully gathered -- fine at the scales this repo
executes on CPU; a production TPU deployment would swap in per-shard writes
behind the same interface).

Checkpoints carry their experiment identity: ``save_checkpoint(...,
spec=...)`` embeds the :class:`repro.core.spec.ExperimentSpec` JSON and its
stable fingerprint alongside the arrays, and ``restore_checkpoint(...,
spec=...)`` REFUSES a resume whose spec fingerprint does not match (the
error message prints both specs, so a mismatched field is one diff away).
Old spec-less checkpoints keep restoring; :func:`saved_spec` reads the
embedded spec back without touching the arrays.
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"

#: reserved npz entry names for the embedded experiment identity (never
#: valid tree paths: leaf keys cannot start with '__spec')
SPEC_JSON_KEY = "__spec_json__"
SPEC_FINGERPRINT_KEY = "__spec_fingerprint__"
_META_KEYS = frozenset({SPEC_JSON_KEY, SPEC_FINGERPRINT_KEY})


def _flatten(tree: PyTree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree, *,
                    spec=None) -> str:
    """Write one atomic npz checkpoint; ``spec`` (an ExperimentSpec) embeds
    the experiment identity for fingerprint-gated resume."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}.npz")
    flat = _flatten(tree)
    if spec is not None:
        flat[SPEC_JSON_KEY] = np.asarray(spec.to_json())
        flat[SPEC_FINGERPRINT_KEY] = np.asarray(spec.fingerprint())
    np.savez(tmp, **flat)  # .npz suffix keeps numpy from renaming
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_latest(ckpt_dir: str, template: PyTree, *, spec=None):
    """Restore the newest checkpoint in ``ckpt_dir`` (the serve replicas'
    resync source): returns ``(step, tree)``, or ``None`` when the directory
    holds no checkpoints.  ``spec`` gates identity exactly as
    :func:`restore_checkpoint`."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return step, restore_checkpoint(ckpt_dir, step, template, spec=spec)


def saved_spec(ckpt_dir: str, step: int):
    """The ExperimentSpec embedded in a checkpoint, or None for a spec-less
    (pre-spec-era) file."""
    from repro.core.spec import ExperimentSpec

    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    if SPEC_JSON_KEY not in data.files:
        return None
    return ExperimentSpec.from_json(str(data[SPEC_JSON_KEY][()]))


def restore_checkpoint(ckpt_dir: str, step: int, template: PyTree, *,
                       spec=None) -> PyTree:
    """Restore a checkpoint into ``template``'s structure.

    ``spec`` gates the resume on experiment identity: the embedded
    fingerprint must match ``spec.fingerprint()`` exactly, otherwise the
    restore is REFUSED with both specs printed (resuming a qsgd:16 run from
    a block_topk checkpoint silently corrupts the control variates -- the
    fingerprint makes that a loud error).  A spec-less checkpoint cannot
    satisfy a spec-gated restore; pass ``spec=None`` to opt out.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    if spec is not None:
        if SPEC_FINGERPRINT_KEY not in data.files:
            raise ValueError(
                f"checkpoint {path} embeds no experiment spec but the "
                "restore is spec-gated; re-save with save_checkpoint(..., "
                "spec=...) or pass spec=None to skip the identity check")
        saved_fp = str(data[SPEC_FINGERPRINT_KEY][()])
        want_fp = spec.fingerprint()
        if saved_fp != want_fp:
            saved_json = str(data[SPEC_JSON_KEY][()]) \
                if SPEC_JSON_KEY in data.files else "<missing>"
            raise ValueError(
                f"refusing resume: checkpoint spec fingerprint {saved_fp} "
                f"!= requested {want_fp}.\n--- checkpoint spec ---\n"
                f"{saved_json}\n--- requested spec ---\n{spec.to_json()}")
    flat = _flatten(template)
    files = set(data.files) - _META_KEYS
    missing = set(flat) - files
    extra = files - set(flat)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path_t, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path_t)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
